"""Serving benchmark: steady-state decode throughput + TTFT percentiles.

Replays seeded synthetic traces from :mod:`repro.serve.trace` (the same
generator the CLI uses — byte-identical workloads for the same seed;
single-engine rows use the closed burst, ``rate=0``, because on CPU the
engine is always the bottleneck and arrival gaps only add noise) through
a greedy :class:`repro.serve.ServeEngine` on the smoke arch and emits:

* ``serve/trace_e2e`` — wall µs to drain the whole fixed seeded trace on a
  warmed *dense-pool* engine (the timed row the regression gate covers:
  per-token decode is a few hundred µs on this arch, under ``diff.py``'s
  noise floor, while the trace wall time sits comfortably above it and
  covers admission + scheduling + decode together); µs/token, tokens/s,
  p50/p95 TTFT and slot occupancy ride the derived column. Dense keeps the
  row comparable across the pool redesign;
* ``serve/paged_e2e`` — the same drain over the default *paged* pool with
  chunked prefill, on a deliberately mixed long/short trace (half the
  prompts span multiple prefill chunks, half fit in one), so the row times
  the page-table gather path plus chunk/decode tick interleaving; pages
  high-water-mark rides the derived column;
* ``serve/preempt_overload`` — the mixed trace drained through a
  deliberately page-starved paged engine under ``admission="incremental"``
  (prompt-only reservation, per-tick growth, preempt-youngest/recompute):
  the row times graceful degradation under oversubscription, and the
  derived column carries the lifecycle counters (``preempted``,
  ``recompute_tokens``, exhaustion events, concurrency high-water-mark)
  that the eager policy structurally cannot exercise;
* ``serve/spec_decode`` — the same-size trace drained with draft-3-
  verify-1 speculative decoding on the *butterfly-compressed* smoke arch
  (the draft head is the model's own fixed-structure butterfly output
  head); the derived column carries the acceptance rate and the gated
  tokens-per-slot-tick figure, which must exceed 1 (asserted in-process —
  greedy speculation is lossless, so the row is pure scheduling speed);
* ``serve/router_slo`` — the multi-replica tier: an *open-loop* Poisson
  trace (rate 100 req/s — arrivals keep coming whether or not the tier
  keeps up) through the :class:`repro.serve.Router` over two warmed paged
  replicas, one TickDriver thread multiplexing both; the derived column
  carries the aggregate p50/p95 TTFT **and end-to-end latency**
  percentiles — the tier's SLO figures — plus dispatch balance and the
  concurrency high-water-mark;
* ``serve/chrome_trace`` — an UNTIMED artifact row: the page-starved
  incremental + speculative trace drained through a one-replica Router
  with a live :class:`repro.obs.Tracer`, exported to
  ``BENCH_serve_trace.json`` (Chrome trace-event JSON; CI validates and
  uploads it). Untimed by design — every gated row above runs under the
  no-op ``NULL_TRACER``, so tracing overhead can never shift the
  regression gate;
* ``serve/large_pool`` — the 16-slot variant, emitted as *skipped* on CPU
  (one tick is minutes of wall clock at that batch) and timed on TPU.

Compile time is excluded from the steady-state number by warming every
trace shape (buckets for dense; the chunk + decode steps for paged) with a
burn-in trace first — the engine's CompileCache makes "warm" checkable
rather than hoped-for.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks import common


def _items(cfg, requests, max_new, *, mix, chunk=16, seed=0, rate=0.0):
    """The shared seeded workload (:mod:`repro.serve.trace`): the SAME
    spec the CLI replays, so bench and CLI serve byte-identical traces
    for the same seed."""
    from repro.serve import trace as trace_lib

    spec = trace_lib.TraceSpec(requests=requests, seed=seed, rate=rate,
                               min_prompt=4, max_prompt=48, mix=mix,
                               chunk=chunk, max_new_tokens=max_new)
    return trace_lib.generate(spec, cfg.vocab_size)


def _drain(engine, prompts, max_new):
    from repro.serve import Request

    futs = [engine.submit(Request(prompt=p, max_new_tokens=max_new))
            for p in prompts]
    engine.run_until_idle()
    for f in futs:
        f.result(0)


def _run_engine(slots: int, requests: int, max_new: int, seed: int = 0,
                pool: str = "dense", admission: str = "eager",
                num_pages=None, arch: str = "smollm-135m-smoke",
                spec_k: int = 0):
    from repro.configs import registry
    from repro.serve import ServeEngine, loader

    cfg = registry.get(arch)
    _, params = loader.load_for_serving(cfg, seed=0)
    engine = ServeEngine(cfg, params, slots=slots, max_len=96, pool=pool,
                         admission=admission, num_pages=num_pages,
                         spec_k=spec_k, seed=seed)
    rng = np.random.default_rng(seed)
    # burn-in: one request per power-of-two bucket warms every dense
    # compile (the paged engine needs just one multi-chunk prompt — chunk
    # prefill + decode + insert/reset cover every trace it will ever
    # take), then the metrics (incl. the tick clock) reset so neither
    # compile wall-time nor cold-TTFT requests leak into the gated
    # snapshot
    burn = (8,) if pool == "paged" else (8, 16, 32, 48)
    _drain(engine, [rng.integers(0, cfg.vocab_size, size=n)
                    for n in (*burn, 48)], 2)
    warm_compiles = engine.compile_stats["compiles"]
    engine.reset_metrics()

    if pool == "paged":
        items = _items(cfg, requests, max_new, mix="bimodal",
                       chunk=engine.prefill_chunk, seed=seed)
    else:
        items = _items(cfg, requests, max_new, mix="uniform", seed=seed)
    prompts = [it.prompt for it in items]
    t0 = time.perf_counter()
    _drain(engine, prompts, max_new)
    wall = time.perf_counter() - t0
    assert engine.compile_stats["compiles"] == warm_compiles, \
        "benchmark trace hit a cold compile; widen the burn-in buckets"
    return engine.metrics.snapshot(), wall


def _run_router(replicas: int, requests: int, max_new: int, rate: float,
                seed: int = 0, slots: int = 2,
                arch: str = "smollm-135m-smoke", admission: str = "eager",
                num_pages=None, spec_k: int = 0, tracer=None):
    """Open-loop SLO run: a seeded Poisson trace at ``rate`` req/s
    replayed through the Router over ``replicas`` warmed paged engines,
    one TickDriver thread multiplexing all of them. Returns the router
    snapshot, the shed count, and the wall seconds from first arrival to
    last result. ``tracer`` (a :class:`repro.obs.Tracer`) records the
    timed drain's span timeline — burn-in spans are wiped by the
    post-warmup ``reset_metrics``."""
    from repro.configs import registry
    from repro.serve import Router, ServeEngine, loader
    from repro.serve import trace as trace_lib

    cfg = registry.get(arch)
    _, params = loader.load_for_serving(cfg, seed=0)
    engines = []
    rng = np.random.default_rng(seed)
    for i in range(replicas):
        e = ServeEngine(cfg, params, slots=slots, max_len=96,
                        pool="paged", admission=admission,
                        num_pages=num_pages, spec_k=spec_k,
                        tracer=tracer, replica=i, seed=seed)
        # same burn-in discipline as the single-engine rows: warm the
        # chunk/decode compiles, then reset so cold TTFTs stay out of
        # the percentiles
        _drain(e, [rng.integers(0, cfg.vocab_size, size=n)
                   for n in (8, 48)], 2)
        e.reset_metrics()
        engines.append(e)
    warm = [e.compile_stats["compiles"] for e in engines]

    items = _items(cfg, requests, max_new, mix="bimodal",
                   chunk=engines[0].prefill_chunk, seed=seed, rate=rate)
    router = Router(engines)
    with router:
        t0 = time.perf_counter()
        futs, shed = trace_lib.replay(router.submit, items)
        for f in futs:
            f.result(timeout=600)
        wall = time.perf_counter() - t0
    assert [e.compile_stats["compiles"] for e in engines] == warm, \
        "router trace hit a cold compile; widen the burn-in buckets"
    return router.snapshot(), shed, wall


def run(requests: int = 24, max_new: int = 8) -> None:
    snap, wall = _run_engine(slots=4, requests=requests, max_new=max_new,
                             pool="dense")
    tok_s = snap["decode_tok_per_s"]
    common.emit(
        "serve/trace_e2e", wall * 1e6,
        f"us_per_tok={1e6 / tok_s:.1f};tok_s={tok_s:.1f};"
        f"p50_ttft_ms={snap['ttft_ms']['p50']};"
        f"p95_ttft_ms={snap['ttft_ms']['p95']};"
        f"occupancy={snap['slot_occupancy']};"
        f"requests={snap['requests_finished']};"
        f"tokens={snap['total_tokens']}")

    snap, wall = _run_engine(slots=4, requests=requests, max_new=max_new,
                             pool="paged")
    tok_s = snap["decode_tok_per_s"]
    common.emit(
        "serve/paged_e2e", wall * 1e6,
        f"us_per_tok={1e6 / tok_s:.1f};tok_s={tok_s:.1f};"
        f"p50_ttft_ms={snap['ttft_ms']['p50']};"
        f"p95_ttft_ms={snap['ttft_ms']['p95']};"
        f"chunk_ticks={snap['chunk_ticks']};"
        f"pages_hwm={snap['pool']['pages_hwm']};"
        f"pages_total={snap['pool']['total_pages']};"
        f"requests={snap['requests_finished']};"
        f"tokens={snap['total_tokens']}")

    # oversubscription: 8 usable 16-token pages across 4 slots cannot hold
    # every admitted request's full budget (a long prompt + 8 new tokens
    # is 4 pages), so incremental admission must grow/preempt/recompute to
    # drain the same mixed trace — the row times that degradation path
    snap, wall = _run_engine(slots=4, requests=requests, max_new=max_new,
                             pool="paged", admission="incremental",
                             num_pages=9)
    tok_s = snap["decode_tok_per_s"]
    common.emit(
        "serve/preempt_overload", wall * 1e6,
        f"us_per_tok={1e6 / tok_s:.1f};tok_s={tok_s:.1f};"
        f"preempted={snap['preempted']};"
        f"recompute_tokens={snap['recompute_tokens']};"
        f"exhausted={snap['pool']['exhausted_events']};"
        f"max_concurrent={snap['max_concurrent_slots']};"
        f"pages_hwm={snap['pool']['pages_hwm']};"
        f"p95_ttft_ms={snap['ttft_ms']['p95']};"
        f"requests={snap['requests_finished']};"
        f"tokens={snap['total_tokens']}")

    # speculative decoding on the butterfly-compressed smoke arch: the
    # draft head IS the model's own butterfly output head, so the row
    # measures the paper's cheap-operator asymmetry doing real scheduling
    # work. The gate: a decode tick must commit MORE than one token per
    # occupied slot on average (greedy speculation is lossless, so this
    # is pure speed) — assert it so the regression diff can't miss it.
    snap, wall = _run_engine(slots=4, requests=requests, max_new=max_new,
                             pool="paged", spec_k=3,
                             arch="smollm-135m-butterfly-smoke")
    tok_s = snap["decode_tok_per_s"]
    sp = snap["spec"]
    assert sp["tokens_per_slot_tick"] > 1.0, (
        f"speculative decode must beat 1 token/slot-tick, got "
        f"{sp['tokens_per_slot_tick']}")
    common.emit(
        "serve/spec_decode", wall * 1e6,
        f"us_per_tok={1e6 / tok_s:.1f};tok_s={tok_s:.1f};"
        f"tokens_per_slot_tick={sp['tokens_per_slot_tick']};"
        f"acceptance_rate={sp['acceptance_rate']};"
        f"spec_k={sp['k']};spec_ticks={sp['ticks']};"
        f"draft_tokens={sp['draft_tokens']};"
        f"accepted_draft_tokens={sp['accepted_draft_tokens']};"
        f"requests={snap['requests_finished']};"
        f"tokens={snap['total_tokens']}")

    # the multi-replica tier under fixed offered load: 2 paged replicas
    # behind the Router, an open-loop Poisson trace (arrivals keep coming
    # whether or not the tier keeps up, so queue depth and tail latency
    # are real), one driver thread round-robining both engines. The row
    # times first-arrival -> last-result; the derived column carries the
    # SLO percentiles (TTFT and end-to-end latency) the router snapshot
    # aggregates across replicas.
    rsnap, shed, wall = _run_router(replicas=2, requests=requests,
                                    max_new=max_new, rate=100.0)
    common.emit(
        "serve/router_slo", wall * 1e6,
        f"p50_ttft_ms={rsnap['ttft_ms']['p50']};"
        f"p95_ttft_ms={rsnap['ttft_ms']['p95']};"
        f"p50_latency_ms={rsnap['latency_ms']['p50']};"
        f"p95_latency_ms={rsnap['latency_ms']['p95']};"
        f"replicas={rsnap['replicas']};"
        f"dispatched={'/'.join(str(p['dispatched']) for p in rsnap['per_replica'])};"
        f"max_concurrent={rsnap['max_concurrent_slots']};"
        f"shed={shed};requeued={rsnap['requeued']};"
        f"requests={rsnap['requests_finished']}")

    # the observability artifact: the page-starved incremental trace with
    # speculative decoding drained through a one-replica Router with a
    # live Tracer, exported as Chrome trace-event JSON. The row is
    # emitted UNTIMED (us_per_call=None — tracing overhead must never
    # enter the regression gate; the timed rows above all run under the
    # no-op NULL_TRACER), validated in-process here and again by the CI
    # step `python -m repro.obs.validate BENCH_serve_trace.json` after
    # upload. The derived column carries the event census so a trace
    # that silently stops covering preemption/speculation fails loudly.
    from repro.obs import Tracer
    from repro.obs.validate import validate_chrome_trace

    tracer = Tracer()
    rsnap, _, _ = _run_router(replicas=1, requests=requests,
                              max_new=max_new, rate=0.0, slots=4,
                              arch="smollm-135m-butterfly-smoke",
                              admission="incremental", num_pages=9,
                              spec_k=3, tracer=tracer)
    events = validate_chrome_trace(tracer.chrome_trace())
    esnap = rsnap["per_replica"][0]["engine"]
    assert esnap["preempted"] > 0, \
        "trace artifact must cover a preemption; re-starve the pool"
    assert esnap["spec"]["draft_tokens"] > 0, \
        "trace artifact must cover speculative decode"
    trace_path = "BENCH_serve_trace.json"
    tracer.write_chrome_trace(trace_path)
    names = {e["name"] for e in events}
    common.emit(
        "serve/chrome_trace", None,
        f"status=artifact;path={trace_path};events={len(events)};"
        f"spans={sum(1 for e in events if e['ph'] == 'X')};"
        f"preempt_events={sum(1 for e in events if e['name'] == 'preempt')};"
        f"spec_spans={sum(1 for e in events if e['name'] == 'spec')};"
        f"has_grow_pages={'grow_pages' in names};"
        f"dropped={tracer.dropped};"
        f"requests={esnap['requests_finished']}")

    if jax.default_backend() == "tpu":
        snap, wall = _run_engine(slots=16, requests=4 * requests,
                                 max_new=max_new, pool="paged")
        tok_s = snap["decode_tok_per_s"]
        common.emit("serve/large_pool", 1e6 / tok_s if tok_s else None,
                    f"tok_s={tok_s:.1f};"
                    f"p95_ttft_ms={snap['ttft_ms']['p95']};"
                    f"occupancy={snap['slot_occupancy']}")
    else:
        common.emit_skipped("serve/large_pool",
                            "16-slot pool too slow on CPU; timed on TPU")
